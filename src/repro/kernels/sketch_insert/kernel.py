"""Pallas kernel: block-binned LSketch batch insertion.

TPU mapping of the paper's hot loop (Algorithm 2, lines 10-23):

  * grid = (n_blocks, n_blocks): one grid step per storage block (mA, mB) —
    the paper's Storage Blocks Division becomes the BlockSpec tiling, so the
    (b, b) tile of `key`/`C`/`P` lives in VMEM for the whole bin.
  * the edge bin of a block arrives as padded rows of a (n^2, max_bin, ...)
    tensor (BlockSpec row-select); padding has weight 0.
  * within a bin, edges are processed in stream order (`fori_loop`) with the
    exact sequential first-fit semantics: s sampled probe cells x 2 twin
    segments, first (key-match | empty) slot wins; failures are flagged for
    the host-side additional-pool path.
  * state tensors are updated in place (input_output_aliases).

``sketch_insert_kernel_sharded`` extends the same body with a leading
**shard** grid dimension — grid ``(n_shards, n_blocks, n_blocks)`` over
``[n_shards, ...]``-stacked bins and state planes, so an N-shard ingest is
one launch instead of N (or a vmapped interpretation). Shards are
independent by construction (hash-partitioned streams, disjoint state
tiles), so the extra grid axis carries no cross-program dependence; the
one kernel body serves both layouts by collapsing whatever leading
singleton block dims its refs carry.

VMEM budget per grid step (b=128, c=8, int32): key 2*128*128*4 = 128 KiB,
C plane 128 KiB, P plane 1 MiB, bin arrays O(max_bin*s) — comfortably inside
the ~16 MiB/core budget; b and max_bin are the tuning knobs (the shard grid
axis adds no VMEM: each program still sees one shard's one tile).

TPU layout note: the twin axis is kept leading ((2, b, b) tiles) so the
trailing two dims are lane/sublane-aligned multiples of (8, 128) when b is a
multiple of 128. Scalar probe reads/writes lower to single-element
dynamic slices — the same access pattern production paged-KV kernels use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EMPTY = -1


def _insert_body(rows_ref, cols_ref, keys_ref, le_ref, w_ref,
                 key_in, c_in, p_in,  # aliased with the out refs below
                 key_ref, c_ref, p_ref, ok_ref,
                 *, s: int, max_bin: int):
    """One storage block: stream the bin through the VMEM tile.

    The state refs are input/output-aliased: ``key_ref``/``c_ref``/``p_ref``
    hold the input tile on entry and are updated in place.

    Works for both grid layouts: the per-block bins/tiles may carry extra
    leading singleton block dims (the shard grid axis); they are collapsed
    by the index prefixes below.
    """
    del key_in, c_in, p_in  # same buffers as the out refs
    bl3 = (0,) * (rows_ref.ndim - 2)  # bins trailing (max_bin, s)
    bl2 = (0,) * (w_ref.ndim - 1)  # bins trailing (max_bin,)
    tl = (0,) * (key_ref.ndim - 3)  # tiles trailing (2, b, b)[, c]

    def edge(i, _):
        w = w_ref[(*bl2, i)]
        # gather the s*2 candidate slots in paper order (probe-major)
        cand = []
        for pi in range(s):
            r = rows_ref[(*bl3, i, pi)]
            c = cols_ref[(*bl3, i, pi)]
            kw = keys_ref[(*bl3, i, pi)]
            for tz in range(2):
                cur = key_ref[(*tl, tz, r, c)]
                cand.append((cur == kw) | (cur == EMPTY))
        okv = jnp.stack(cand)  # [s*2]
        found = okv.any() & (w > 0)
        first = jnp.argmax(okv)
        pi_sel = first // 2
        tz_sel = first % 2

        # select the winning coordinates (static gather over s alternatives)
        r_sel = jnp.int32(0)
        c_sel = jnp.int32(0)
        k_sel = jnp.int32(0)
        for pi in range(s):
            hit = pi_sel == pi
            r_sel = jnp.where(hit, rows_ref[(*bl3, i, pi)], r_sel)
            c_sel = jnp.where(hit, cols_ref[(*bl3, i, pi)], c_sel)
            k_sel = jnp.where(hit, keys_ref[(*bl3, i, pi)], k_sel)

        old_key = jnp.where(tz_sel == 0, key_ref[(*tl, 0, r_sel, c_sel)],
                            key_ref[(*tl, 1, r_sel, c_sel)])
        new_key = jnp.where(found, k_sel, old_key)
        wm = jnp.where(found, w, 0)
        le = le_ref[(*bl2, i)]

        for tz in range(2):
            sel = (tz_sel == tz) & found
            key_ref[(*tl, tz, r_sel, c_sel)] = jnp.where(
                sel, new_key, key_ref[(*tl, tz, r_sel, c_sel)])
            c_ref[(*tl, tz, r_sel, c_sel)] = \
                c_ref[(*tl, tz, r_sel, c_sel)] + jnp.where(sel, wm, 0)
            p_ref[(*tl, tz, r_sel, c_sel, le)] = \
                p_ref[(*tl, tz, r_sel, c_sel, le)] + jnp.where(sel, wm, 0)
        ok_ref[(*bl2, i)] = found
        return _

    jax.lax.fori_loop(0, max_bin, edge, 0)


@functools.partial(jax.jit, static_argnames=("n_blocks", "b", "s", "c",
                                             "max_bin", "interpret"))
def sketch_insert_kernel(rows, cols, keys, le, w, key, C_plane, P_plane,
                         *, n_blocks: int, b: int, s: int, c: int,
                         max_bin: int, interpret: bool = True):
    """rows/cols: [n^2, max_bin, s] block-relative probe coords;
    keys: [n^2, max_bin, s]; le/w: [n^2, max_bin];
    key/C_plane: [2, d, d]; P_plane: [2, d, d, c]  (current-slot planes).

    Returns (key, C_plane, P_plane, inserted_flags[n^2, max_bin]).
    """
    n2 = n_blocks * n_blocks
    grid = (n_blocks, n_blocks)

    bin_spec3 = pl.BlockSpec((1, max_bin, s), lambda i, j: (i * n_blocks + j, 0, 0))
    bin_spec2 = pl.BlockSpec((1, max_bin), lambda i, j: (i * n_blocks + j, 0))
    tile = pl.BlockSpec((2, b, b), lambda i, j: (0, i, j))
    tile_p = pl.BlockSpec((2, b, b, c), lambda i, j: (0, i, j, 0))

    out = pl.pallas_call(
        functools.partial(_insert_body, s=s, max_bin=max_bin),
        grid=grid,
        in_specs=[bin_spec3, bin_spec3, bin_spec3, bin_spec2, bin_spec2,
                  tile, tile, tile_p],
        out_specs=[tile, tile, tile_p, bin_spec2],
        out_shape=[
            jax.ShapeDtypeStruct(key.shape, key.dtype),
            jax.ShapeDtypeStruct(C_plane.shape, C_plane.dtype),
            jax.ShapeDtypeStruct(P_plane.shape, P_plane.dtype),
            jax.ShapeDtypeStruct((n2, max_bin), jnp.bool_),
        ],
        input_output_aliases={5: 0, 6: 1, 7: 2},
        interpret=interpret,
    )(rows, cols, keys, le, w, key, C_plane, P_plane)
    return out


@functools.partial(jax.jit, static_argnames=("n_shards", "n_blocks", "b",
                                             "s", "c", "max_bin"))
def sketch_insert_tiles_xla(rows, cols, keys, le, w, key, C_plane, P_plane,
                            limit=None, *, n_shards: int, n_blocks: int,
                            b: int, s: int, c: int, max_bin: int):
    """Pure-XLA twin of ``sketch_insert_kernel_sharded`` — same I/O
    contract, bit-identical results: the executable model of the Pallas
    kernel (tests assert kernel == twin on identical binned inputs).

    The kernel's grid axes are embarrassingly parallel (all ``s`` probes of
    an edge live inside one (row-block, col-block) tile, so bins never
    share a matrix cell); only the walk *within* a bin is sequential. This
    twin exploits exactly that: one ``lax.while_loop`` over bin positions
    whose body processes **one edge of every (shard, block) bin
    simultaneously** (vectorized gathers/scatters over the
    ``n_shards * n_blocks^2`` tile axis). The production CPU path goes one
    step further (``sketch_insert_stream_walk`` below: no materialized
    bins, counters out of the loop); this twin stays shaped exactly like
    the kernel so the two can be diffed tensor-for-tensor.

    ``limit`` (traced scalar, optional): the largest actual bin fill across
    all bins. Positions >= the fill of every bin are provable no-ops (the
    binning pads with weight 0, and zero weight neither claims nor adds),
    so the walk stops there instead of grinding through ``max_bin``.
    """
    S, n2 = n_shards, n_blocks * n_blocks
    NB = S * n2
    nb_idx = jnp.arange(NB, dtype=jnp.int32)
    limit = jnp.int32(max_bin) if limit is None else \
        jnp.minimum(jnp.asarray(limit, jnp.int32), max_bin)

    def to_tiles(plane):  # [S, 2, d, d(, c)] -> [NB, 2, b, b(, c)]
        extra = plane.shape[4:]
        x = plane.reshape((S, 2, n_blocks, b, n_blocks, b) + extra)
        x = jnp.moveaxis(x, (2, 4), (1, 2))
        return x.reshape((NB, 2, b, b) + extra)

    def from_tiles(tiles):  # inverse of to_tiles
        extra = tiles.shape[4:]
        x = tiles.reshape((S, n_blocks, n_blocks, 2, b, b) + extra)
        x = jnp.moveaxis(x, (1, 2), (2, 4))
        d = n_blocks * b
        return x.reshape((S, 2, d, d) + extra)

    def to_stream(x):  # [S, n2, max_bin, ...] -> [max_bin, NB, ...]
        flat = x.reshape((NB, max_bin) + x.shape[3:])
        return jnp.moveaxis(flat, 1, 0)

    xs = tuple(to_stream(v) for v in (rows, cols, keys, le, w))

    def body(state):
        t, key_t, C_t, P_t, flags = state
        r, cc, kk, le_t, w_t = (x[t] for x in xs)  # [NB, s] x3, [NB], [NB]
        # the s*2 candidates in paper order (probe-major, twin-minor)
        cur = key_t[nb_idx[:, None, None], jnp.arange(2)[None, None, :],
                    r[:, :, None], cc[:, :, None]]  # [NB, s, 2]
        ok = ((cur == kk[:, :, None]) | (cur == EMPTY)).reshape(NB, -1)
        found = ok.any(axis=1) & (w_t > 0)
        first = jnp.argmax(ok, axis=1)
        pi, tz = first // 2, first % 2
        take = lambda a: jnp.take_along_axis(a, pi[:, None], axis=1)[:, 0]
        r_sel, c_sel, k_sel = take(r), take(cc), take(kk)
        old = key_t[nb_idx, tz, r_sel, c_sel]
        wm = jnp.where(found, w_t, 0)
        key_t = key_t.at[nb_idx, tz, r_sel, c_sel].set(
            jnp.where(found, k_sel, old))
        C_t = C_t.at[nb_idx, tz, r_sel, c_sel].add(wm)
        P_t = P_t.at[nb_idx, tz, r_sel, c_sel, le_t].add(wm)
        return t + 1, key_t, C_t, P_t, flags.at[t].set(found)

    state = (jnp.int32(0), to_tiles(key), to_tiles(C_plane),
             to_tiles(P_plane), jnp.zeros((max_bin, NB), jnp.bool_))
    _, key_t, C_t, P_t, flags = jax.lax.while_loop(
        lambda st: st[0] < limit, body, state)
    flags = jnp.moveaxis(flags, 0, 1).reshape(S, n2, max_bin)
    return from_tiles(key_t), from_tiles(C_t), from_tiles(P_t), flags


def sketch_insert_stream_walk(rows, cols, keys, w, order, offs, counts,
                              key, *, n_shards: int, n_blocks: int, b: int,
                              max_bin: int | None = None):
    """The sequential half of the binned insert, alone: walk the key tiles
    in bin order and *collect* each edge's landing cell instead of
    updating counter planes.

    Two observations make this the fast XLA lowering of the binned
    program (``pallas_call`` on CPU only interprets):

      * the first-fit walk reads and writes only ``key`` — the ``C``/``P``
        counters are write-only scatter-adds, so they need not ride
        through the sequential loop at all; the caller applies all counter
        weight in one vectorized scatter-add into the full stacked state
        (no ring-slot plane gather, no tile reshape, no write-back copy);
      * bins never need materializing — the loop reads edge ``t`` of every
        bin straight out of the bin-sorted stream (a gather at
        ``offs + t``), so the ``[n2, max_bin, ...]`` padding tensors the
        hardware kernel's BlockSpecs require are never built.

    Inputs: ``rows``/``cols`` ([S, B, s], **tile-relative** probe coords,
    stream order), ``keys`` [S, B, s], ``w`` [S, B] (weights already
    carrying every mask — zero weight neither claims nor counts),
    ``order`` [S, B] (stable bin sort), ``offs``/``counts`` [S, n2] (bin
    start/fill within each shard's sorted stream), ``key`` [S, 2, d, d].

    Returns ``(new_key [S, 2, d, d], enc [S, B])`` where ``enc`` is per
    item **in stream order**: 0 = not inserted (pool candidate iff its
    weight is positive), else ``1 + (tz * b + r_rel) * b + c_rel`` — the
    landing cell, packed. Walk length is ``min(max(counts), max_bin)``:
    the true largest bin fill, not the padded batch length — and capped
    at ``max_bin`` so a tuned bin capacity drops each bin's overflow
    edges to the pool exactly like the hardware kernel's truncated bins
    (an un-walked edge keeps ``enc == 0``). Traced (not jitted) —
    compose inside a jitted caller.
    """
    S, n2 = n_shards, n_blocks * n_blocks
    B = w.shape[1]
    NB = S * n2
    nb_idx = jnp.arange(NB, dtype=jnp.int32)
    limit = jnp.max(counts)
    if max_bin is not None:
        limit = jnp.minimum(limit, jnp.int32(max_bin))

    def flat_sorted(x):  # [S, B, ...] -> bin-sorted, shard-flattened
        idx = order if x.ndim == 2 else order[..., None]
        return jnp.take_along_axis(x, idx, axis=1).reshape((S * B,)
                                                           + x.shape[2:])

    rows_s, cols_s, keys_s, w_s = (flat_sorted(v)
                                   for v in (rows, cols, keys, w))
    # global sorted position of bin nb's first edge
    base = (nb_idx // n2) * jnp.int32(B) + offs.reshape(NB)
    counts_f = counts.reshape(NB)

    def body(state):
        t, key_t, enc_s = state
        live = t < counts_f  # [NB]
        gi = jnp.where(live, base + t, jnp.int32(S * B))  # OOB -> clamp/drop
        r = rows_s[jnp.minimum(gi, S * B - 1)]  # [NB, s]
        cc = cols_s[jnp.minimum(gi, S * B - 1)]
        kk = keys_s[jnp.minimum(gi, S * B - 1)]
        w_t = jnp.where(live, w_s[jnp.minimum(gi, S * B - 1)], 0)
        cur = key_t[nb_idx[:, None, None], jnp.arange(2)[None, None, :],
                    r[:, :, None], cc[:, :, None]]  # [NB, s, 2]
        ok = ((cur == kk[:, :, None]) | (cur == EMPTY)).reshape(NB, -1)
        found = ok.any(axis=1) & (w_t > 0)
        first = jnp.argmax(ok, axis=1)
        pi, tz = first // 2, first % 2
        take = lambda a: jnp.take_along_axis(a, pi[:, None], axis=1)[:, 0]
        r_sel, c_sel, k_sel = take(r), take(cc), take(kk)
        old = key_t[nb_idx, tz, r_sel, c_sel]
        key_t = key_t.at[nb_idx, tz, r_sel, c_sel].set(
            jnp.where(found, k_sel, old))
        # packed collect write: 0 = not inserted, else 1 + cell id
        enc = jnp.where(found, 1 + (tz * b + r_sel) * b + c_sel, 0)
        return t + 1, key_t, enc_s.at[gi].set(enc, mode="drop")

    key_t = jnp.moveaxis(key.reshape(S, 2, n_blocks, b, n_blocks, b),
                         (2, 4), (1, 2)).reshape(NB, 2, b, b)
    state = (jnp.int32(0), key_t, jnp.zeros((S * B,), jnp.int32))
    _, key_t, enc_s = jax.lax.while_loop(lambda st: st[0] < limit, body,
                                         state)

    x = key_t.reshape(S, n_blocks, n_blocks, 2, b, b)
    new_key = jnp.moveaxis(x, (1, 2), (2, 4)).reshape(S, 2, n_blocks * b,
                                                      n_blocks * b)
    # un-sort the collect array back to stream order
    enc = jnp.zeros((S, B), jnp.int32).at[
        jnp.arange(S, dtype=jnp.int32)[:, None], order].set(
            enc_s.reshape(S, B))
    return new_key, enc


@functools.partial(jax.jit, static_argnames=("n_shards", "n_blocks", "b",
                                             "s", "c", "max_bin",
                                             "interpret"))
def sketch_insert_kernel_sharded(rows, cols, keys, le, w, key, C_plane,
                                 P_plane, *, n_shards: int, n_blocks: int,
                                 b: int, s: int, c: int, max_bin: int,
                                 interpret: bool = True):
    """Shard-axis variant: one launch over every shard's every block.

    rows/cols/keys: [n_shards, n^2, max_bin, s]; le/w: [n_shards, n^2,
    max_bin]; key/C_plane: [n_shards, 2, d, d]; P_plane: [n_shards, 2, d,
    d, c] (each shard's current-slot planes, gathered at its own ring
    slot by the caller).

    Returns (key, C_plane, P_plane, inserted_flags[n_shards, n^2,
    max_bin]). Grid ``(n_shards, n_blocks, n_blocks)`` — the shard axis is
    the outermost (slowest) grid dimension, so each shard's tiles stream
    through VMEM contiguously, exactly like n_shards back-to-back launches
    of ``sketch_insert_kernel`` but with one dispatch and one pipeline.
    """
    n2 = n_blocks * n_blocks
    grid = (n_shards, n_blocks, n_blocks)

    bin_spec4 = pl.BlockSpec((1, 1, max_bin, s),
                             lambda h, i, j: (h, i * n_blocks + j, 0, 0))
    bin_spec3 = pl.BlockSpec((1, 1, max_bin),
                             lambda h, i, j: (h, i * n_blocks + j, 0))
    tile = pl.BlockSpec((1, 2, b, b), lambda h, i, j: (h, 0, i, j))
    tile_p = pl.BlockSpec((1, 2, b, b, c), lambda h, i, j: (h, 0, i, j, 0))

    out = pl.pallas_call(
        functools.partial(_insert_body, s=s, max_bin=max_bin),
        grid=grid,
        in_specs=[bin_spec4, bin_spec4, bin_spec4, bin_spec3, bin_spec3,
                  tile, tile, tile_p],
        out_specs=[tile, tile, tile_p, bin_spec3],
        out_shape=[
            jax.ShapeDtypeStruct(key.shape, key.dtype),
            jax.ShapeDtypeStruct(C_plane.shape, C_plane.dtype),
            jax.ShapeDtypeStruct(P_plane.shape, P_plane.dtype),
            jax.ShapeDtypeStruct((n_shards, n2, max_bin), jnp.bool_),
        ],
        input_output_aliases={5: 0, 6: 1, 7: 2},
        interpret=interpret,
    )(rows, cols, keys, le, w, key, C_plane, P_plane)
    return out
