"""Pallas kernel: block-binned LSketch batch insertion.

TPU mapping of the paper's hot loop (Algorithm 2, lines 10-23):

  * grid = (n_blocks, n_blocks): one grid step per storage block (mA, mB) —
    the paper's Storage Blocks Division becomes the BlockSpec tiling, so the
    (b, b) tile of `key`/`C`/`P` lives in VMEM for the whole bin.
  * the edge bin of a block arrives as padded rows of a (n^2, max_bin, ...)
    tensor (BlockSpec row-select); padding has weight 0.
  * within a bin, edges are processed in stream order (`fori_loop`) with the
    exact sequential first-fit semantics: s sampled probe cells x 2 twin
    segments, first (key-match | empty) slot wins; failures are flagged for
    the host-side additional-pool path.
  * state tensors are updated in place (input_output_aliases).

VMEM budget per grid step (b=128, c=8, int32): key 2*128*128*4 = 128 KiB,
C plane 128 KiB, P plane 1 MiB, bin arrays O(max_bin*s) — comfortably inside
the ~16 MiB/core budget; b and max_bin are the tuning knobs.

TPU layout note: the twin axis is kept leading ((2, b, b) tiles) so the
trailing two dims are lane/sublane-aligned multiples of (8, 128) when b is a
multiple of 128. Scalar probe reads/writes lower to single-element
dynamic slices — the same access pattern production paged-KV kernels use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EMPTY = -1


def _insert_body(rows_ref, cols_ref, keys_ref, le_ref, w_ref,
                 key_in, c_in, p_in,  # aliased with the out refs below
                 key_ref, c_ref, p_ref, ok_ref,
                 *, s: int, max_bin: int):
    """One storage block: stream the bin through the VMEM tile.

    The state refs are input/output-aliased: ``key_ref``/``c_ref``/``p_ref``
    hold the input tile on entry and are updated in place.
    """
    del key_in, c_in, p_in  # same buffers as the out refs

    def edge(i, _):
        w = w_ref[0, i]
        # gather the s*2 candidate slots in paper order (probe-major)
        cand = []
        for pi in range(s):
            r = rows_ref[0, i, pi]
            c = cols_ref[0, i, pi]
            kw = keys_ref[0, i, pi]
            for tz in range(2):
                cur = key_ref[tz, r, c]
                cand.append((cur == kw) | (cur == EMPTY))
        okv = jnp.stack(cand)  # [s*2]
        found = okv.any() & (w > 0)
        first = jnp.argmax(okv)
        pi_sel = first // 2
        tz_sel = first % 2

        # select the winning coordinates (static gather over s alternatives)
        r_sel = jnp.int32(0)
        c_sel = jnp.int32(0)
        k_sel = jnp.int32(0)
        for pi in range(s):
            hit = pi_sel == pi
            r_sel = jnp.where(hit, rows_ref[0, i, pi], r_sel)
            c_sel = jnp.where(hit, cols_ref[0, i, pi], c_sel)
            k_sel = jnp.where(hit, keys_ref[0, i, pi], k_sel)

        old_key = jnp.where(tz_sel == 0, key_ref[0, r_sel, c_sel],
                            key_ref[1, r_sel, c_sel])
        new_key = jnp.where(found, k_sel, old_key)
        wm = jnp.where(found, w, 0)
        le = le_ref[0, i]

        for tz in range(2):
            sel = (tz_sel == tz) & found
            key_ref[tz, r_sel, c_sel] = jnp.where(sel, new_key,
                                                  key_ref[tz, r_sel, c_sel])
            c_ref[tz, r_sel, c_sel] = c_ref[tz, r_sel, c_sel] + jnp.where(
                sel, wm, 0)
            p_ref[tz, r_sel, c_sel, le] = p_ref[tz, r_sel, c_sel, le] + \
                jnp.where(sel, wm, 0)
        ok_ref[0, i] = found
        return _

    jax.lax.fori_loop(0, max_bin, edge, 0)


@functools.partial(jax.jit, static_argnames=("n_blocks", "b", "s", "c",
                                             "max_bin", "interpret"))
def sketch_insert_kernel(rows, cols, keys, le, w, key, C_plane, P_plane,
                         *, n_blocks: int, b: int, s: int, c: int,
                         max_bin: int, interpret: bool = True):
    """rows/cols: [n^2, max_bin, s] block-relative probe coords;
    keys: [n^2, max_bin, s]; le/w: [n^2, max_bin];
    key/C_plane: [2, d, d]; P_plane: [2, d, d, c]  (current-slot planes).

    Returns (key, C_plane, P_plane, inserted_flags[n^2, max_bin]).
    """
    n2 = n_blocks * n_blocks
    grid = (n_blocks, n_blocks)

    bin_spec3 = pl.BlockSpec((1, max_bin, s), lambda i, j: (i * n_blocks + j, 0, 0))
    bin_spec2 = pl.BlockSpec((1, max_bin), lambda i, j: (i * n_blocks + j, 0))
    tile = pl.BlockSpec((2, b, b), lambda i, j: (0, i, j))
    tile_p = pl.BlockSpec((2, b, b, c), lambda i, j: (0, i, j, 0))

    out = pl.pallas_call(
        functools.partial(_insert_body, s=s, max_bin=max_bin),
        grid=grid,
        in_specs=[bin_spec3, bin_spec3, bin_spec3, bin_spec2, bin_spec2,
                  tile, tile, tile_p],
        out_specs=[tile, tile, tile_p, bin_spec2],
        out_shape=[
            jax.ShapeDtypeStruct(key.shape, key.dtype),
            jax.ShapeDtypeStruct(C_plane.shape, C_plane.dtype),
            jax.ShapeDtypeStruct(P_plane.shape, P_plane.dtype),
            jax.ShapeDtypeStruct((n2, max_bin), jnp.bool_),
        ],
        input_output_aliases={5: 0, 6: 1, 7: 2},
        interpret=interpret,
    )(rows, cols, keys, le, w, key, C_plane, P_plane)
    return out
