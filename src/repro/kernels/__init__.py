"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel package ships three files:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (binning, window-plane slicing, fallbacks)
  ref.py    — pure-jnp oracle, the correctness contract

Kernels:
  sketch_insert   — block-binned batched LSketch insertion. The paper's
                    Storage Blocks Division *is* the BlockSpec tiling: grid
                    cell (mA, mB) owns the (b, b) tile of the storage matrix,
                    streams its bin of edges through VMEM, first-fit probes
                    twin cells exactly like the sequential algorithm.
  sketch_query    — batched edge-weight queries on window-reduced planes.
  vertex_scan     — batched vertex aggregate queries (r-row masked reduction).
  flash_attention — blockwise-softmax causal attention for the LM substrate.

This container is CPU-only: kernels are *validated* with interpret=True
(Python execution of the kernel body) against ref.py across shape/dtype
sweeps; TPU is the compile target.
"""
