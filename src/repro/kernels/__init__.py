"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel package ships three files:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (binning, window-plane slicing, fallbacks)
  ref.py    — pure-jnp oracle, the correctness contract

Kernels:
  sketch_insert   — block-binned batched LSketch insertion. The paper's
                    Storage Blocks Division *is* the BlockSpec tiling: grid
                    cell (mA, mB) owns the (b, b) tile of the storage matrix,
                    streams its bin of edges through VMEM, first-fit probes
                    twin cells exactly like the sequential algorithm.
  sketch_query    — batched edge-weight queries on window-reduced planes
                    (shard-axis grid (n_shards, query_chunks) + compiled
                    XLA lowering; DESIGN.md §8).
  vertex_scan     — batched vertex/label aggregate queries (r-row masked
                    reduction; same shard-axis grid + XLA lowering).
  flash_attention — blockwise-softmax causal attention for the LM substrate.

This container is CPU-only: kernels are *validated* with interpret=True
(Python execution of the kernel body) against ref.py across shape/dtype
sweeps and against their compiled XLA lowerings (the production CPU
routes — the insert/query "pallas" paths never interpret in production);
TPU is the compile target.
"""
