"""Pallas kernel: batched LSketch edge-weight queries.

Grid = query chunks; the window-reduced state planes (key / Cw / Pw) are
VMEM-resident for the whole call (BlockSpec = whole array; fits for d <= 512
with small c — the telemetry regime; larger sketches use the block-binned
formulation of sketch_insert).

Per query the kernel replays the insertion walk: s probe cells x 2 twins in
order, stopping at the first key match (weight found) or first empty slot
(edge provably absent from the matrix). The all-occupied-mismatch case sets
``go_pool`` and is resolved by the wrapper with a vectorized pool lookup.

``sketch_query_kernel_sharded`` extends the same body with a leading
**shard** grid dimension — grid ``(n_shards, query_chunks)`` over
``[n_shards, ...]``-stacked planes: every query is answered against every
shard's planes (query blocks are broadcast along the shard axis; the
per-shard partials are summed by the wrapper — the handle layer's exact
combinator). The one body serves both layouts by collapsing whatever
leading singleton block dims its refs carry, exactly like
``sketch_insert``.

``sketch_query_xla`` is the compiled pure-XLA lowering of the same walk
(``pallas_call`` on CPU only interprets): the stop-at-first-(match|empty)
walk is a static ``s*2`` argmax, vectorized over shards x queries — the
production CPU route of the "pallas" query path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EMPTY = -1


def _query_body(rows_ref, cols_ref, keys_ref, le_ref,
                key_ref, cw_ref, pw_ref,
                w_ref, wl_ref, pool_ref, *, s: int, chunk: int):
    """One query chunk against one shard's planes.

    Works for both grid layouts: the query/output blocks and the plane
    tiles may carry extra leading singleton block dims (the shard grid
    axis); they are collapsed by the index prefixes below.
    """
    q3 = (0,) * (rows_ref.ndim - 2)  # query blocks trailing (chunk, s)
    q1 = (0,) * (le_ref.ndim - 1)  # per-query in blocks trailing (chunk,)
    o1 = (0,) * (w_ref.ndim - 1)  # out blocks trailing (chunk,)
    tl = (0,) * (key_ref.ndim - 3)  # plane tiles trailing (2, d, d)[, c]

    def one(q, _):
        # ordered probe walk, stop at first (match | empty)
        done = jnp.bool_(False)
        hit = jnp.bool_(False)
        w = jnp.int32(0)
        wl = jnp.int32(0)
        le = le_ref[(*q1, q)]
        for pi in range(s):
            r = rows_ref[(*q3, q, pi)]
            c = cols_ref[(*q3, q, pi)]
            kw = keys_ref[(*q3, q, pi)]
            for tz in range(2):
                cur = key_ref[(*tl, tz, r, c)]
                is_m = (cur == kw) & ~done
                is_e = (cur == EMPTY) & ~done
                w = jnp.where(is_m, cw_ref[(*tl, tz, r, c)], w)
                wl = jnp.where(is_m, pw_ref[(*tl, tz, r, c, le)], wl)
                hit = hit | is_m
                done = done | is_m | is_e
        w_ref[(*o1, q)] = w
        wl_ref[(*o1, q)] = wl
        pool_ref[(*o1, q)] = ~done  # every slot occupied-mismatch -> pool
        return _

    jax.lax.fori_loop(0, chunk, one, 0)


@functools.partial(jax.jit, static_argnames=("d", "s", "c", "chunk", "interpret"))
def sketch_query_kernel(rows, cols, keys, le, key_plane, cw, pw,
                        *, d: int, s: int, c: int, chunk: int = 128,
                        interpret: bool = True):
    """rows/cols/keys: [nq, s]; le: [nq] label-bucket index;
    key_plane/cw: [2, d, d]; pw: [2, d, d, c].
    Returns (w [nq], w_label [nq], go_pool [nq])."""
    nq = rows.shape[0]
    assert nq % chunk == 0, "pad queries to a chunk multiple"
    grid = (nq // chunk,)
    qs3 = pl.BlockSpec((1, chunk, s), lambda i: (i, 0, 0))
    qs2 = pl.BlockSpec((1, chunk), lambda i: (i, 0))
    full3 = pl.BlockSpec(key_plane.shape, lambda i: (0, 0, 0))
    full4 = pl.BlockSpec(pw.shape, lambda i: (0, 0, 0, 0))
    w, wl, go_pool = pl.pallas_call(
        functools.partial(_query_body, s=s, chunk=chunk),
        grid=grid,
        in_specs=[qs3, qs3, qs3, qs2, full3, full3, full4],
        out_specs=[qs2, qs2, qs2],
        out_shape=[
            jax.ShapeDtypeStruct((nq // chunk, chunk), cw.dtype),
            jax.ShapeDtypeStruct((nq // chunk, chunk), pw.dtype),
            jax.ShapeDtypeStruct((nq // chunk, chunk), jnp.bool_),
        ],
        interpret=interpret,
    )(rows.reshape(nq // chunk, chunk, s), cols.reshape(nq // chunk, chunk, s),
      keys.reshape(nq // chunk, chunk, s), le.reshape(nq // chunk, chunk),
      key_plane, cw, pw)
    return w.reshape(nq), wl.reshape(nq), go_pool.reshape(nq)


@functools.partial(jax.jit, static_argnames=("n_shards", "d", "s", "c",
                                             "chunk", "interpret"))
def sketch_query_kernel_sharded(rows, cols, keys, le, key_plane, cw, pw,
                                *, n_shards: int, d: int, s: int, c: int,
                                chunk: int = 128, interpret: bool = True):
    """Shard-axis variant: every query against every shard's planes.

    rows/cols/keys: [nq, s]; le: [nq] (shared across shards — the handle
    layer fans one query batch through all shards);
    key_plane/cw: [n_shards, 2, d, d]; pw: [n_shards, 2, d, d, c].
    Returns (w, w_label, go_pool), each [n_shards, nq].

    Grid ``(n_shards, nq // chunk)`` — shard axis outermost, so each
    shard's planes stay VMEM-resident while its query chunks stream
    through, exactly like n_shards back-to-back launches of
    ``sketch_query_kernel`` with one dispatch and one pipeline.
    """
    nq = rows.shape[0]
    assert nq % chunk == 0, "pad queries to a chunk multiple"
    nch = nq // chunk
    grid = (n_shards, nch)
    qs3 = pl.BlockSpec((1, chunk, s), lambda h, i: (i, 0, 0))
    qs2 = pl.BlockSpec((1, chunk), lambda h, i: (i, 0))
    out2 = pl.BlockSpec((1, 1, chunk), lambda h, i: (h, i, 0))
    plane3 = pl.BlockSpec((1,) + key_plane.shape[1:], lambda h, i: (h, 0, 0, 0))
    plane4 = pl.BlockSpec((1,) + pw.shape[1:], lambda h, i: (h, 0, 0, 0, 0))
    w, wl, go_pool = pl.pallas_call(
        functools.partial(_query_body, s=s, chunk=chunk),
        grid=grid,
        in_specs=[qs3, qs3, qs3, qs2, plane3, plane3, plane4],
        out_specs=[out2, out2, out2],
        out_shape=[
            jax.ShapeDtypeStruct((n_shards, nch, chunk), cw.dtype),
            jax.ShapeDtypeStruct((n_shards, nch, chunk), pw.dtype),
            jax.ShapeDtypeStruct((n_shards, nch, chunk), jnp.bool_),
        ],
        interpret=interpret,
    )(rows.reshape(nch, chunk, s), cols.reshape(nch, chunk, s),
      keys.reshape(nch, chunk, s), le.reshape(nch, chunk),
      key_plane, cw, pw)
    return (w.reshape(n_shards, nq), wl.reshape(n_shards, nq),
            go_pool.reshape(n_shards, nq))


def sketch_query_xla(rows, cols, keys, le_idx, key_plane, cw, pw):
    """Compiled pure-XLA twin of ``sketch_query_kernel_sharded`` — same
    I/O contract, bit-identical results (integer adds/selects only).

    rows/cols/keys: [nq, s]; le_idx: [nq] or None (skip the label plane);
    key_plane/cw: [S, 2, d, d]; pw: [S, 2, d, d, c].
    Returns (w [S, nq], w_label [S, nq], go_pool [S, nq]).

    The walk needs no loop at all: per query the s*2 candidates are
    gathered in paper order (probe-major, twin-minor) and the first
    (match | empty) is a static argmax — the same formulation as the
    dense reference, but on window-reduced planes (no ``k`` axis rides
    the gathers). Traced (not jitted) — compose inside a jitted caller.
    """
    S = key_plane.shape[0]
    nq, s = rows.shape
    # [S, nq, s, 2] candidates in paper order
    cur = key_plane[:, :, rows, cols]  # [S, 2, nq, s]
    cur = jnp.moveaxis(cur, 1, -1)  # [S, nq, s, 2]
    is_m = (cur == keys[None, :, :, None]).reshape(S, nq, s * 2)
    is_e = (cur == EMPTY).reshape(S, nq, s * 2)
    stop = is_m | is_e
    any_stop = stop.any(-1)
    first = jnp.argmax(stop, -1)  # [S, nq]
    hit = jnp.take_along_axis(is_m, first[..., None], -1)[..., 0] & any_stop
    pi, tz = first // 2, first % 2
    rr = jnp.take_along_axis(jnp.broadcast_to(rows, (S, nq, s)),
                             pi[..., None], -1)[..., 0]
    cc = jnp.take_along_axis(jnp.broadcast_to(cols, (S, nq, s)),
                             pi[..., None], -1)[..., 0]
    s_idx = jnp.arange(S, dtype=jnp.int32)[:, None]
    w = jnp.where(hit, cw[s_idx, tz, rr, cc], 0)
    if le_idx is None:
        wl = jnp.zeros_like(w)
    else:
        wl = jnp.where(hit, pw[s_idx, tz, rr, cc,
                               le_idx[None, :].astype(jnp.int32)], 0)
    return w, wl, ~any_stop
