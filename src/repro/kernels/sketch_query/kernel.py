"""Pallas kernel: batched LSketch edge-weight queries.

Grid = query chunks; the window-reduced state planes (key / Cw / Pw) are
VMEM-resident for the whole call (BlockSpec = whole array; fits for d <= 512
with small c — the telemetry regime; larger sketches use the block-binned
formulation of sketch_insert).

Per query the kernel replays the insertion walk: s probe cells x 2 twins in
order, stopping at the first key match (weight found) or first empty slot
(edge provably absent from the matrix). The all-occupied-mismatch case sets
``go_pool`` and is resolved by the wrapper with a vectorized pool lookup.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EMPTY = -1


def _query_body(rows_ref, cols_ref, keys_ref, le_ref,
                key_ref, cw_ref, pw_ref,
                w_ref, wl_ref, pool_ref, *, s: int, chunk: int):
    def one(q, _):
        # ordered probe walk, stop at first (match | empty)
        done = jnp.bool_(False)
        hit = jnp.bool_(False)
        w = jnp.int32(0)
        wl = jnp.int32(0)
        le = le_ref[0, q]
        for pi in range(s):
            r = rows_ref[0, q, pi]
            c = cols_ref[0, q, pi]
            kw = keys_ref[0, q, pi]
            for tz in range(2):
                cur = key_ref[tz, r, c]
                is_m = (cur == kw) & ~done
                is_e = (cur == EMPTY) & ~done
                w = jnp.where(is_m, cw_ref[tz, r, c], w)
                wl = jnp.where(is_m, pw_ref[tz, r, c, le], wl)
                hit = hit | is_m
                done = done | is_m | is_e
        w_ref[0, q] = w
        wl_ref[0, q] = wl
        pool_ref[0, q] = ~done  # every slot occupied-mismatch -> ask the pool
        return _

    jax.lax.fori_loop(0, chunk, one, 0)


@functools.partial(jax.jit, static_argnames=("d", "s", "c", "chunk", "interpret"))
def sketch_query_kernel(rows, cols, keys, le, key_plane, cw, pw,
                        *, d: int, s: int, c: int, chunk: int = 128,
                        interpret: bool = True):
    """rows/cols/keys: [nq, s]; le: [nq] label-bucket index;
    key_plane/cw: [2, d, d]; pw: [2, d, d, c].
    Returns (w [nq], w_label [nq], go_pool [nq])."""
    nq = rows.shape[0]
    assert nq % chunk == 0, "pad queries to a chunk multiple"
    grid = (nq // chunk,)
    qs3 = pl.BlockSpec((1, chunk, s), lambda i: (i, 0, 0))
    qs2 = pl.BlockSpec((1, chunk), lambda i: (i, 0))
    full3 = pl.BlockSpec(key_plane.shape, lambda i: (0, 0, 0))
    full4 = pl.BlockSpec(pw.shape, lambda i: (0, 0, 0, 0))
    w, wl, go_pool = pl.pallas_call(
        functools.partial(_query_body, s=s, chunk=chunk),
        grid=grid,
        in_specs=[qs3, qs3, qs3, qs2, full3, full3, full4],
        out_specs=[qs2, qs2, qs2],
        out_shape=[
            jax.ShapeDtypeStruct((nq // chunk, chunk), cw.dtype),
            jax.ShapeDtypeStruct((nq // chunk, chunk), pw.dtype),
            jax.ShapeDtypeStruct((nq // chunk, chunk), jnp.bool_),
        ],
        interpret=interpret,
    )(rows.reshape(nq // chunk, chunk, s), cols.reshape(nq // chunk, chunk, s),
      keys.reshape(nq // chunk, chunk, s), le.reshape(nq // chunk, chunk),
      key_plane, cw, pw)
    return w.reshape(nq), wl.reshape(nq), go_pool.reshape(nq)
