"""Wrappers for the batched edge-query kernel: planes walk + pool path.

``edge_query_planes`` is the composable middle of the "pallas" query path
(DESIGN.md §8): it takes pre-reduced ``QueryPlanes`` (shard-stacked) plus
a query batch and answers every query against every shard — the matrix
probe walk on the kernel (TPU) or its compiled XLA lowering (everywhere
else; the pallas path never interprets), plus the vectorized pool lookup
for all-occupied-mismatch queries. ``repro.sketch.query`` routes through
it; ``edge_query_pallas`` is the standalone single-sketch drop-in kept
for tests and direct use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hashing as hsh
from repro.core.lsketch import edge_probes, precompute
from repro.core.queries import QueryPlanes, build_query_planes
from repro.core.types import LSketchConfig, LSketchState

from .kernel import (sketch_query_kernel, sketch_query_kernel_sharded,
                     sketch_query_xla)

__all__ = ["edge_query_planes", "edge_query_pallas", "sketch_query_kernel"]


def _pad_to(x, mult, fill=0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    padding = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, padding, constant_values=fill), n


def edge_query_planes(cfg: LSketchConfig, planes: QueryPlanes, src, dst,
                      labels, with_le: bool = True, interpret: bool = True,
                      _kernel_interpret: bool = False,
                      axis_name: str | None = None):
    """Batched edge queries on window-reduced planes, all shards at once.

    src/dst: int32 [B]; labels: (lA, lB, le) int32 [B] each (``le`` is
    ignored when ``with_le`` is False). Returns (w, w_label), each
    [S, B] — per-shard partials; the caller sums over the shard axis
    (hash partitioning makes shard estimates disjoint).

    ``interpret=True`` (the non-TPU setting) routes the matrix walk to
    the compiled XLA lowering — bit-identical, never interpreted.
    ``_kernel_interpret`` (tests only): run the hardware-kernel branch in
    Pallas interpret mode — the only way to exercise it on CPU.
    Traced (not jitted) — compose inside a jitted caller.

    ``axis_name`` makes this a ``shard_map``-compatible entry point
    (DESIGN.md §9): the planes then carry only the device-local shard
    block ``[S_local, ...]`` and the outputs come back reduced to ``[B]``
    via ``core.merge.psum_partials`` (local sum + cross-device psum) —
    the collective query's one reduction point.

    Horizon-stacked ``MultiPlanes`` (5-dim ``cw``, DESIGN.md §14) are
    accepted via the same leading-axis collapse the shard stack uses:
    ``[H, S, ...]`` reshapes to ``[H*S, ...]``, the walk runs once, and
    the partials fold back per horizon. The multi outputs come back
    ``[H, B]`` ALREADY shard-reduced (psum-reduced too under
    ``axis_name``) — callers must not re-sum a shard axis.
    """
    if planes.cw.ndim == 5:  # horizon-stacked MultiPlanes
        H, S = planes.cw.shape[:2]
        flat = jax.tree.map(
            lambda x: jnp.reshape(x, (H * S,) + x.shape[2:]), planes)
        w, wl = edge_query_planes(cfg, flat, src, dst, labels,
                                  with_le=with_le, interpret=interpret,
                                  _kernel_interpret=_kernel_interpret)
        w = jnp.sum(w.reshape((H, S) + w.shape[1:]), axis=1)
        wl = jnp.sum(wl.reshape((H, S) + wl.shape[1:]), axis=1)
        if axis_name is not None:
            w = jax.lax.psum(w, axis_name)
            wl = jax.lax.psum(wl, axis_name)
        return w, wl
    la, lb, le = labels
    pa = precompute(cfg, src, la)
    pb = precompute(cfg, dst, lb)
    pr = edge_probes(cfg, pa, pb)
    le_idx = hsh.edge_label_bucket(le, cfg.c, cfg.seed) if with_le else None
    S = planes.cw.shape[0]

    if interpret and not _kernel_interpret:
        w, wl, go_pool = sketch_query_xla(pr.rows, pr.cols, pr.keys, le_idx,
                                          planes.key, planes.cw, planes.pw)
    else:
        rows, n = _pad_to(pr.rows, 128)
        cols, _ = _pad_to(pr.cols, 128)
        keys, _ = _pad_to(pr.keys, 128, fill=-2)  # never matches, never EMPTY
        lei, _ = _pad_to(le_idx if le_idx is not None
                         else jnp.zeros_like(pr.rows[:, 0]), 128)
        w, wl, go_pool = sketch_query_kernel_sharded(
            rows, cols, keys, lei, planes.key, planes.cw, planes.pw,
            n_shards=S, d=cfg.d, s=cfg.s, c=cfg.c,
            interpret=_kernel_interpret)
        w, wl, go_pool = w[:, :n], wl[:, :n], go_pool[:, :n]
        if le_idx is None:
            wl = jnp.zeros_like(w)

    # pool lookup for all-occupied-mismatch queries (vectorized, per shard)
    ps = hsh.pool_slot_seq(pr.pid_src, pr.pid_dst, cfg.pool_capacity,
                           cfg.pool_probes, cfg.seed)  # [B, probes]
    pk = planes.pool_key[:, ps]  # [S, B, probes, 2]
    pmatch = (pk[..., 0] == pr.pid_src[None, :, None]) & \
        (pk[..., 1] == pr.pid_dst[None, :, None])
    pany = pmatch.any(-1)  # [S, B]
    pfirst = jnp.argmax(pmatch, -1)
    pslot = jnp.take_along_axis(jnp.broadcast_to(ps, (S,) + ps.shape),
                                pfirst[..., None], -1)[..., 0]  # [S, B]
    s_idx = jnp.arange(S, dtype=jnp.int32)[:, None]
    sel = go_pool & pany
    w = w + jnp.where(sel, planes.pool_cw[s_idx, pslot], 0)
    if le_idx is not None:
        wl_p = planes.pool_pw[s_idx, pslot, le_idx[None, :].astype(jnp.int32)]
        wl = wl + jnp.where(sel, wl_p, 0)
    from repro.core.merge import maybe_psum_partials
    return maybe_psum_partials(w, wl, axis_name)


@functools.partial(jax.jit, static_argnums=(0, 5),
                   static_argnames=("interpret",))
def _edge_query_pallas(cfg: LSketchConfig, state: LSketchState, src, dst,
                       labels, last: int | None = None, *,
                       interpret: bool = True):
    lifted = jax.tree.map(lambda x: x[None], state)
    planes = build_query_planes(cfg, lifted, last)
    w, wl = edge_query_planes(cfg, planes, src, dst, labels, with_le=True,
                              interpret=interpret)
    return w[0], wl[0]


def edge_query_pallas(cfg: LSketchConfig, state: LSketchState, src, dst,
                      labels, last: int | None = None,
                      interpret: bool | None = None):
    """Kernel-backed equivalent of ``repro.core.edge_query`` (both outputs).

    ``interpret`` is backend-derived by default (True off TPU, same rule
    as the insert kernels) and only meaningful on the real Pallas branch:
    with ``interpret=True`` the walk runs as the compiled XLA lowering —
    the pallas query path never interprets.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _edge_query_pallas(cfg, state, src, dst, labels, last,
                              interpret=interpret)
