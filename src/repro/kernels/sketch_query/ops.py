"""Wrapper for the batched edge-query kernel: window reduction, pool path."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hashing as hsh
from repro.core.lsketch import edge_probes, precompute, valid_slot_mask
from repro.core.types import LSketchConfig, LSketchState

from .kernel import sketch_query_kernel


def _pad_to(x, mult, fill=0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    padding = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, padding, constant_values=fill), n


@functools.partial(jax.jit, static_argnums=(0, 5), static_argnames=("interpret",))
def edge_query_pallas(cfg: LSketchConfig, state: LSketchState, src, dst,
                      labels, last: int | None = None, interpret: bool = True):
    """Kernel-backed equivalent of ``repro.core.edge_query`` (both outputs)."""
    la, lb, le = labels
    pa = precompute(cfg, src, la)
    pb = precompute(cfg, dst, lb)
    pr = edge_probes(cfg, pa, pb)
    le_idx = hsh.edge_label_bucket(le, cfg.c, cfg.seed)
    mask = valid_slot_mask(cfg, state, last).astype(state.C.dtype)

    key_plane = jnp.moveaxis(state.key, 2, 0)
    cw = jnp.moveaxis(jnp.sum(state.C * mask, -1), 2, 0)
    pw = jnp.moveaxis(jnp.sum(state.P * mask[:, None], -2), 2, 0)

    rows, n = _pad_to(pr.rows, 128)
    cols, _ = _pad_to(pr.cols, 128)
    keys, _ = _pad_to(pr.keys, 128, fill=-2)  # -2 never matches, never EMPTY
    lei, _ = _pad_to(le_idx, 128)
    w, wl, go_pool = sketch_query_kernel(
        rows, cols, keys, lei, key_plane, cw, pw,
        d=cfg.d, s=cfg.s, c=cfg.c, interpret=interpret)
    w, wl, go_pool = w[:n], wl[:n], go_pool[:n]

    # pool lookup for all-occupied-mismatch queries (vectorized)
    ps = hsh.pool_slot_seq(pr.pid_src, pr.pid_dst, cfg.pool_capacity,
                           cfg.pool_probes, cfg.seed)
    pk = state.pool_key[ps]
    pmatch = (pk[..., 0] == pr.pid_src[:, None]) & (pk[..., 1] == pr.pid_dst[:, None])
    pany = pmatch.any(-1)
    pfirst = jnp.argmax(pmatch, -1)
    pslot = jnp.take_along_axis(ps, pfirst[:, None], -1)[:, 0]
    maskk = valid_slot_mask(cfg, state, last).astype(state.pool_C.dtype)
    w_p = jnp.sum(state.pool_C[pslot] * maskk, -1)
    wl_p = jnp.take_along_axis(
        jnp.sum(state.pool_P[pslot] * maskk[:, None], -2),
        le_idx[:, None].astype(jnp.int32), -1)[:, 0]
    sel = go_pool & pany
    return w + jnp.where(sel, w_p, 0), wl + jnp.where(sel, wl_p, 0)
