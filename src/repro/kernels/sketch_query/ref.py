"""Oracle for sketch_query: ``repro.core.edge_query`` with with_edge_label
True/False — the pure-jnp path validated against the paper-literal Python
implementation. The kernel must agree exactly (integer counters)."""

from repro.core.queries import edge_query as reference_edge_query

__all__ = ["reference_edge_query"]
