"""Oracle for vertex_scan: ``repro.core.vertex_query`` (pure jnp)."""

from repro.core.queries import vertex_query as reference_vertex_query

__all__ = ["reference_vertex_query"]
