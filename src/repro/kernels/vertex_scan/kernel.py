"""Pallas kernel: batched vertex aggregate queries (paper Algorithm 4).

The TPU-shaped sketch query: for each queried vertex, its r candidate rows
are scanned across all d columns x 2 twins — a masked reduction that maps
straight onto the VPU (row loads are contiguous lane vectors; the key-field
decode is integer element-wise math; the label select is a one-hot dot).

Grid = query chunks; state planes VMEM-resident as in sketch_query.
``vertex_scan_kernel_sharded`` adds the leading shard grid dimension
(grid ``(n_shards, query_chunks)``, query blocks broadcast along the
shard axis) with the same body — leading singleton block dims are
collapsed, exactly like ``sketch_insert``/``sketch_query``.

``vertex_scan_xla`` is the compiled pure-XLA lowering of the same scan
(the production CPU route of the "pallas" query path): one static unroll
over the r candidate rows, each iteration gathering one row (or column —
``direction="in"`` decodes the destination key fields instead of
transposing planes) of the window-reduced planes for all shards x
queries. Peak intermediate is [S, 2, B, d(, c)] — the label axis never
multiplies the r axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EMPTY = -1
IDX_RADIX = 16


def _scan_body(lines_ref, f_ref, le_ref, key_ref, cw_ref, pw_ref,
               w_ref, wl_ref, *, r: int, F: int, c: int, chunk: int):
    q2 = (0,) * (lines_ref.ndim - 2)  # query blocks trailing (chunk, r)
    q1 = (0,) * (f_ref.ndim - 1)  # per-query in blocks trailing (chunk,)
    o1 = (0,) * (w_ref.ndim - 1)  # out blocks trailing (chunk,)
    tl = (0,) * (key_ref.ndim - 3)  # plane tiles trailing (2, d, d)[, c]

    def one(q, _):
        f = f_ref[(*q1, q)]
        le = le_ref[(*q1, q)]
        w = jnp.int32(0)
        wl = jnp.int32(0)
        for i in range(r):  # static unroll over candidate rows
            row = lines_ref[(*q2, q, i)]
            krow = key_ref[(*tl, slice(None), row, slice(None))]  # [2, d]
            rest = krow // jnp.int32(F)
            fa = rest % jnp.int32(F)
            ia = (rest // jnp.int32(F)) // jnp.int32(IDX_RADIX)
            match = (krow != EMPTY) & (ia == i) & (fa == f)
            w = w + jnp.sum(jnp.where(
                match, cw_ref[(*tl, slice(None), row, slice(None))], 0))
            onehot = (jnp.arange(c, dtype=jnp.int32) == le).astype(jnp.int32)
            prow = jnp.sum(
                pw_ref[(*tl, slice(None), row, slice(None), slice(None))]
                * onehot, axis=-1)  # [2, d]
            wl = wl + jnp.sum(jnp.where(match, prow, 0))
        w_ref[(*o1, q)] = w
        wl_ref[(*o1, q)] = wl
        return _

    jax.lax.fori_loop(0, chunk, one, 0)


@functools.partial(jax.jit, static_argnames=("r", "F", "c", "chunk", "interpret"))
def vertex_scan_kernel(lines, f, le, key_plane, cw, pw,
                       *, r: int, F: int, c: int, chunk: int = 128,
                       interpret: bool = True):
    """lines: [nq, r] absolute candidate rows; f/le: [nq];
    key_plane/cw: [2, d, d]; pw: [2, d, d, c].
    Returns (w [nq], w_label [nq])."""
    nq = lines.shape[0]
    assert nq % chunk == 0
    grid = (nq // chunk,)
    qs2 = pl.BlockSpec((1, chunk, r), lambda i: (i, 0, 0))
    qs1 = pl.BlockSpec((1, chunk), lambda i: (i, 0))
    full3 = pl.BlockSpec(key_plane.shape, lambda i: (0, 0, 0))
    full4 = pl.BlockSpec(pw.shape, lambda i: (0, 0, 0, 0))
    w, wl = pl.pallas_call(
        functools.partial(_scan_body, r=r, F=F, c=c, chunk=chunk),
        grid=grid,
        in_specs=[qs2, qs1, qs1, full3, full3, full4],
        out_specs=[qs1, qs1],
        out_shape=[
            jax.ShapeDtypeStruct((nq // chunk, chunk), cw.dtype),
            jax.ShapeDtypeStruct((nq // chunk, chunk), pw.dtype),
        ],
        interpret=interpret,
    )(lines.reshape(nq // chunk, chunk, r), f.reshape(nq // chunk, chunk),
      le.reshape(nq // chunk, chunk), key_plane, cw, pw)
    return w.reshape(nq), wl.reshape(nq)


@functools.partial(jax.jit, static_argnames=("n_shards", "r", "F", "c",
                                             "chunk", "interpret"))
def vertex_scan_kernel_sharded(lines, f, le, key_plane, cw, pw,
                               *, n_shards: int, r: int, F: int, c: int,
                               chunk: int = 128, interpret: bool = True):
    """Shard-axis variant: every query scanned on every shard's planes.

    lines: [nq, r]; f/le: [nq] (shared across shards);
    key_plane/cw: [n_shards, 2, d, d]; pw: [n_shards, 2, d, d, c].
    Returns (w, w_label), each [n_shards, nq]. Grid
    ``(n_shards, nq // chunk)`` — shard planes VMEM-resident while their
    query chunks stream through.
    """
    nq = lines.shape[0]
    assert nq % chunk == 0
    nch = nq // chunk
    grid = (n_shards, nch)
    qs2 = pl.BlockSpec((1, chunk, r), lambda h, i: (i, 0, 0))
    qs1 = pl.BlockSpec((1, chunk), lambda h, i: (i, 0))
    out2 = pl.BlockSpec((1, 1, chunk), lambda h, i: (h, i, 0))
    plane3 = pl.BlockSpec((1,) + key_plane.shape[1:], lambda h, i: (h, 0, 0, 0))
    plane4 = pl.BlockSpec((1,) + pw.shape[1:], lambda h, i: (h, 0, 0, 0, 0))
    w, wl = pl.pallas_call(
        functools.partial(_scan_body, r=r, F=F, c=c, chunk=chunk),
        grid=grid,
        in_specs=[qs2, qs1, qs1, plane3, plane3, plane4],
        out_specs=[out2, out2],
        out_shape=[
            jax.ShapeDtypeStruct((n_shards, nch, chunk), cw.dtype),
            jax.ShapeDtypeStruct((n_shards, nch, chunk), pw.dtype),
        ],
        interpret=interpret,
    )(lines.reshape(nch, chunk, r), f.reshape(nch, chunk),
      le.reshape(nch, chunk), key_plane, cw, pw)
    return w.reshape(n_shards, nq), wl.reshape(n_shards, nq)


def vertex_scan_xla(lines, f, le_idx, key_plane, cw, pw, *, r: int, F: int,
                    direction: str = "out"):
    """Compiled pure-XLA twin of ``vertex_scan_kernel_sharded`` — same
    results bit-identically (integer adds only), plus the "in" direction
    natively: instead of transposing the planes and swapping packed key
    fields, it gathers candidate *columns* and decodes the destination
    fields (i_B, f_B) directly.

    lines: [nq, r] absolute candidate rows (out) / cols (in); f/le_idx:
    [nq] (le_idx None skips the label plane); key_plane/cw: [S, 2, d, d];
    pw: [S, 2, d, d, c]. Returns (w [S, nq], w_label [S, nq]).
    Traced (not jitted) — compose inside a jitted caller.
    """
    from repro.core import hashing as hsh

    S = key_plane.shape[0]
    nq = lines.shape[0]
    w = jnp.zeros((S, nq), cw.dtype)
    wl = jnp.zeros((S, nq), pw.dtype)
    for i in range(r):  # static unroll: peak transient [S, 2, nq, d(, c)]
        li = lines[:, i]  # [nq]
        if direction == "out":
            kg = key_plane[:, :, li]  # [S, 2, nq, d]
            cg = cw[:, :, li]
        else:
            kg = jnp.moveaxis(key_plane[:, :, :, li], 3, 2)  # -> [S, 2, nq, d]
            cg = jnp.moveaxis(cw[:, :, :, li], 3, 2)
        ia, ib, fa, fb = hsh.unpack_key(kg, F)
        idx, fp = (ia, fa) if direction == "out" else (ib, fb)
        match = (kg != EMPTY) & (idx == i) & (fp == f[None, :, None])
        w = w + jnp.sum(jnp.where(match, cg, 0), axis=(1, 3))
        if le_idx is not None:
            if direction == "out":
                pg = pw[:, :, li]  # [S, 2, nq, d, c]
            else:
                pg = jnp.moveaxis(pw[:, :, :, li], 3, 2)
            pl_sel = jnp.take_along_axis(
                pg, le_idx[None, None, :, None, None].astype(jnp.int32),
                -1)[..., 0]  # [S, 2, nq, d]
            wl = wl + jnp.sum(jnp.where(match, pl_sel, 0), axis=(1, 3))
    return w, wl
