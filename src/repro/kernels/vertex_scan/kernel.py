"""Pallas kernel: batched vertex aggregate queries (paper Algorithm 4).

The TPU-shaped sketch query: for each queried vertex, its r candidate rows
are scanned across all d columns x 2 twins — a masked reduction that maps
straight onto the VPU (row loads are contiguous lane vectors; the key-field
decode is integer element-wise math; the label select is a one-hot dot).

Grid = query chunks; state planes VMEM-resident as in sketch_query.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EMPTY = -1
IDX_RADIX = 16


def _scan_body(lines_ref, f_ref, le_ref, key_ref, cw_ref, pw_ref,
               w_ref, wl_ref, *, r: int, F: int, c: int, chunk: int):
    def one(q, _):
        f = f_ref[0, q]
        le = le_ref[0, q]
        w = jnp.int32(0)
        wl = jnp.int32(0)
        for i in range(r):  # static unroll over candidate rows
            row = lines_ref[0, q, i]
            krow = key_ref[:, row, :]  # [2, d] contiguous lane vector
            rest = krow // jnp.int32(F)
            fa = rest % jnp.int32(F)
            ia = (rest // jnp.int32(F)) // jnp.int32(IDX_RADIX)
            match = (krow != EMPTY) & (ia == i) & (fa == f)
            w = w + jnp.sum(jnp.where(match, cw_ref[:, row, :], 0))
            onehot = (jnp.arange(c, dtype=jnp.int32) == le).astype(jnp.int32)
            prow = jnp.sum(pw_ref[:, row, :, :] * onehot, axis=-1)  # [2, d]
            wl = wl + jnp.sum(jnp.where(match, prow, 0))
        w_ref[0, q] = w
        wl_ref[0, q] = wl
        return _

    jax.lax.fori_loop(0, chunk, one, 0)


@functools.partial(jax.jit, static_argnames=("r", "F", "c", "chunk", "interpret"))
def vertex_scan_kernel(lines, f, le, key_plane, cw, pw,
                       *, r: int, F: int, c: int, chunk: int = 128,
                       interpret: bool = True):
    """lines: [nq, r] absolute candidate rows; f/le: [nq];
    key_plane/cw: [2, d, d]; pw: [2, d, d, c].
    Returns (w [nq], w_label [nq])."""
    nq = lines.shape[0]
    assert nq % chunk == 0
    grid = (nq // chunk,)
    qs2 = pl.BlockSpec((1, chunk, r), lambda i: (i, 0, 0))
    qs1 = pl.BlockSpec((1, chunk), lambda i: (i, 0))
    full3 = pl.BlockSpec(key_plane.shape, lambda i: (0, 0, 0))
    full4 = pl.BlockSpec(pw.shape, lambda i: (0, 0, 0, 0))
    w, wl = pl.pallas_call(
        functools.partial(_scan_body, r=r, F=F, c=c, chunk=chunk),
        grid=grid,
        in_specs=[qs2, qs1, qs1, full3, full3, full4],
        out_specs=[qs1, qs1],
        out_shape=[
            jax.ShapeDtypeStruct((nq // chunk, chunk), cw.dtype),
            jax.ShapeDtypeStruct((nq // chunk, chunk), pw.dtype),
        ],
        interpret=interpret,
    )(lines.reshape(nq // chunk, chunk, r), f.reshape(nq // chunk, chunk),
      le.reshape(nq // chunk, chunk), key_plane, cw, pw)
    return w.reshape(nq), wl.reshape(nq)
