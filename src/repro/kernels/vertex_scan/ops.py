"""Wrappers for the vertex/label aggregate query kernels (out/in, pool).

``vertex_query_planes`` and ``label_aggregate_planes`` are the vertex-side
middles of the "pallas" query path (DESIGN.md §8), operating on pre-reduced
shard-stacked ``QueryPlanes``:

  * vertex aggregates run the r-row masked scan — the shard-axis Pallas
    kernel on TPU, its compiled XLA lowering elsewhere (never interpreted);
  * label aggregates are a dense masked reduction over the planes (matmul-
    shaped already — there is no per-query walk to kernelize, so both
    backends share the one XLA formulation; the plane cache is the win).

``vertex_query_pallas`` is the standalone single-sketch drop-in kept for
tests and direct use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hashing as hsh
from repro.core.lsketch import precompute
from repro.core.queries import QueryPlanes, build_query_planes
from repro.core.types import EMPTY, LSketchConfig, LSketchState

from repro.kernels.sketch_query.ops import _pad_to

from .kernel import (vertex_scan_kernel, vertex_scan_kernel_sharded,
                     vertex_scan_xla)

__all__ = ["vertex_query_planes", "label_aggregate_planes",
           "vertex_query_pallas", "vertex_scan_kernel"]


def vertex_query_planes(cfg: LSketchConfig, planes: QueryPlanes, vertex,
                        labels, direction: str = "out", with_le: bool = True,
                        interpret: bool = True,
                        _kernel_interpret: bool = False,
                        axis_name: str | None = None):
    """Batched vertex aggregate queries on window-reduced planes.

    vertex: int32 [B]; labels: (lv, le) int32 [B] each (``le`` ignored when
    ``with_le`` is False). Returns (w, w_label), each [S, B] per-shard
    partials. ``interpret``/``_kernel_interpret`` as in
    ``edge_query_planes``; ``axis_name`` likewise makes this a
    ``shard_map``-compatible entry point returning ``[B]`` outputs reduced
    via ``core.merge.psum_partials`` (DESIGN.md §9).
    Traced — compose inside a jitted caller.

    Horizon-stacked ``MultiPlanes`` (5-dim ``cw``, DESIGN.md §14) collapse
    their leading ``[H]`` into the shard axis, scan once, and return
    ``[H, B]`` ALREADY shard-reduced (and psum-reduced under
    ``axis_name``) — callers must not re-sum a shard axis.
    """
    if planes.cw.ndim == 5:  # horizon-stacked MultiPlanes
        H, S = planes.cw.shape[:2]
        flat = jax.tree.map(
            lambda x: jnp.reshape(x, (H * S,) + x.shape[2:]), planes)
        w, wl = vertex_query_planes(cfg, flat, vertex, labels,
                                    direction=direction, with_le=with_le,
                                    interpret=interpret,
                                    _kernel_interpret=_kernel_interpret)
        w = jnp.sum(w.reshape((H, S) + w.shape[1:]), axis=1)
        wl = jnp.sum(wl.reshape((H, S) + wl.shape[1:]), axis=1)
        if axis_name is not None:
            w = jax.lax.psum(w, axis_name)
            wl = jax.lax.psum(wl, axis_name)
        return w, wl
    lv, le = labels
    pre = precompute(cfg, vertex, lv)
    le_idx = hsh.edge_label_bucket(le, cfg.c, cfg.seed) if with_le else None
    pos = (pre.s[:, None] + pre.offs) % pre.width[:, None]
    lines = pre.start[:, None] + pos  # [B, r] absolute row (or col) index
    S = planes.cw.shape[0]

    if interpret and not _kernel_interpret:
        w, wl = vertex_scan_xla(lines, pre.f, le_idx, planes.key, planes.cw,
                                planes.pw, r=cfg.r, F=cfg.F,
                                direction=direction)
    else:
        key_plane, cw, pw = planes.key, planes.cw, planes.pw
        if direction == "in":  # scan columns: transpose planes and swap the
            # (ia, fa) <-> (ib, fb) packed-key fields so the kernel's
            # "row-owner" decode reads the destination fields
            key_plane = jnp.swapaxes(key_plane, 2, 3)
            cw = jnp.swapaxes(cw, 2, 3)
            pw = jnp.swapaxes(pw, 2, 3)
            occupied = key_plane != EMPTY
            ia, ib, fa, fb = hsh.unpack_key(key_plane, cfg.F)
            key_plane = jnp.where(occupied,
                                  hsh.pack_key(ib, ia, fb, fa, cfg.F),
                                  key_plane)
        linesP, n = _pad_to(lines, 128)
        fP, _ = _pad_to(pre.f, 128, fill=-3)  # never matches a fingerprint
        leP, _ = _pad_to(le_idx if le_idx is not None
                         else jnp.zeros_like(pre.f), 128)
        w, wl = vertex_scan_kernel_sharded(
            linesP, fP, leP, key_plane, cw, pw, n_shards=S, r=cfg.r,
            F=cfg.F, c=cfg.c, interpret=_kernel_interpret)
        w, wl = w[:, :n], wl[:, :n]
        if le_idx is None:
            wl = jnp.zeros_like(w)

    # pool contribution: match the stored endpoint id, per shard
    col = 0 if direction == "out" else 1
    pm = planes.pool_key[:, :, col][:, None, :] == pre.vid[None, :, None]
    w = w + jnp.sum(jnp.where(pm, planes.pool_cw[:, None, :], 0), -1)
    if le_idx is not None:
        B = pre.vid.shape[0]
        lw = jnp.take_along_axis(
            jnp.broadcast_to(planes.pool_pw[:, None],
                             (S, B) + planes.pool_pw.shape[1:]),
            le_idx[None, :, None, None].astype(jnp.int32), -1)[..., 0]
        wl = wl + jnp.sum(jnp.where(pm, lw, 0), -1)
    from repro.core.merge import maybe_psum_partials
    return maybe_psum_partials(w, wl, axis_name)


def label_aggregate_planes(cfg: LSketchConfig, planes: QueryPlanes, vlabel,
                           edge_label=None, direction: str = "out",
                           with_le: bool = False,
                           axis_name: str | None = None):
    """Vertex-label aggregates on window-reduced planes (Alg. 4 lines
    10-14): sum every occupied cell in the label's block rows (out) /
    columns (in) plus matching pool entries. Returns (w, w_label) [S, B],
    or ``[B]`` psum-reduced when ``axis_name`` is set (the shard_map
    collective entry, DESIGN.md §9). Horizon-stacked ``MultiPlanes``
    (5-dim ``cw``) collapse like the other plane ops and return ``[H, B]``
    ALREADY shard-reduced — callers must not re-sum a shard axis.
    """
    if planes.cw.ndim == 5:  # horizon-stacked MultiPlanes (DESIGN.md §14)
        H, S = planes.cw.shape[:2]
        flat = jax.tree.map(
            lambda x: jnp.reshape(x, (H * S,) + x.shape[2:]), planes)
        w, wl = label_aggregate_planes(cfg, flat, vlabel,
                                       edge_label=edge_label,
                                       direction=direction, with_le=with_le)
        w = jnp.sum(w.reshape((H, S) + w.shape[1:]), axis=1)
        wl = jnp.sum(wl.reshape((H, S) + wl.shape[1:]), axis=1)
        if axis_name is not None:
            w = jax.lax.psum(w, axis_name)
            wl = jax.lax.psum(wl, axis_name)
        return w, wl
    vlabel = jnp.asarray(vlabel, jnp.int32)
    B = vlabel.shape[0]
    S = planes.cw.shape[0]
    le_idx = hsh.edge_label_bucket(edge_label, cfg.c, cfg.seed) \
        if with_le else None
    starts, widths = cfg.block_start_width()
    m = hsh.vertex_label_block(vlabel, cfg.n_blocks, cfg.seed)
    rows = jnp.arange(cfg.d, dtype=jnp.int32)
    in_block = (rows[None, :] >= starts[m][:, None]) & (
        rows[None, :] < (starts[m] + widths[m])[:, None])  # [B, d]
    occ = planes.key != EMPTY  # [S, 2, d, d]
    cell_tot = planes.cw * occ
    axis_tot = cell_tot.sum(axis=(1, 3)) if direction == "out" \
        else cell_tot.sum(axis=(1, 2))  # [S, d]
    w = jnp.sum(in_block[None] * axis_tot[:, None, :], -1)  # [S, B]
    wl = jnp.zeros_like(w)
    if with_le:
        Pc = planes.pw * occ[..., None]
        per_lbl = Pc.sum(axis=(1, 3)) if direction == "out" \
            else Pc.sum(axis=(1, 2))  # [S, d, c]
        lw = jnp.take_along_axis(
            jnp.broadcast_to(per_lbl[:, None], (S, B) + per_lbl.shape[1:]),
            le_idx[None, :, None, None].astype(jnp.int32), -1)[..., 0]
        wl = jnp.sum(in_block[None] * lw, -1)
    # pool: endpoint block id stored inside the packed vid
    col = 0 if direction == "out" else 1
    pcol = planes.pool_key[:, :, col]  # [S, Q]
    pm_blocks, _, _ = hsh.unpack_vertex_id(pcol, cfg.F)
    pmatch = (pcol != EMPTY)[:, None, :] & \
        (pm_blocks[:, None, :] == m[None, :, None])  # [S, B, Q]
    w = w + jnp.sum(jnp.where(pmatch, planes.pool_cw[:, None, :], 0), -1)
    if with_le:
        plw = jnp.take_along_axis(
            jnp.broadcast_to(planes.pool_pw[:, None],
                             (S, B) + planes.pool_pw.shape[1:]),
            le_idx[None, :, None, None].astype(jnp.int32), -1)[..., 0]
        wl = wl + jnp.sum(jnp.where(pmatch, plw, 0), -1)
    from repro.core.merge import maybe_psum_partials
    return maybe_psum_partials(w, wl, axis_name)


@functools.partial(jax.jit, static_argnums=(0, 4, 5),
                   static_argnames=("interpret",))
def _vertex_query_pallas(cfg: LSketchConfig, state: LSketchState, vertex,
                         labels, direction: str = "out",
                         last: int | None = None, *, interpret: bool = True):
    lifted = jax.tree.map(lambda x: x[None], state)
    planes = build_query_planes(cfg, lifted, last)
    w, wl = vertex_query_planes(cfg, planes, vertex, labels,
                                direction=direction, with_le=True,
                                interpret=interpret)
    return w[0], wl[0]


def vertex_query_pallas(cfg: LSketchConfig, state: LSketchState, vertex,
                        labels, direction: str = "out",
                        last: int | None = None,
                        interpret: bool | None = None):
    """Kernel-backed equivalent of ``repro.core.vertex_query``.

    ``interpret`` is backend-derived by default (True off TPU, same rule
    as the insert kernels): the compiled XLA lowering runs everywhere the
    real Pallas kernel can't — the pallas query path never interprets.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _vertex_query_pallas(cfg, state, vertex, labels, direction, last,
                                interpret=interpret)
