"""Wrapper for the vertex aggregate query kernel (out/in, pool included)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hashing as hsh
from repro.core.lsketch import precompute, valid_slot_mask
from repro.core.types import LSketchConfig, LSketchState

from .kernel import vertex_scan_kernel


@functools.partial(jax.jit, static_argnums=(0, 4, 5),
                   static_argnames=("interpret",))
def vertex_query_pallas(cfg: LSketchConfig, state: LSketchState, vertex,
                        labels, direction: str = "out",
                        last: int | None = None, interpret: bool = True):
    """Kernel-backed equivalent of ``repro.core.vertex_query``."""
    lv, le = labels
    pre = precompute(cfg, vertex, lv)
    le_idx = hsh.edge_label_bucket(le, cfg.c, cfg.seed)
    mask = valid_slot_mask(cfg, state, last).astype(state.C.dtype)

    key_plane = jnp.moveaxis(state.key, 2, 0)
    cw = jnp.moveaxis(jnp.sum(state.C * mask, -1), 2, 0)
    pw = jnp.moveaxis(jnp.sum(state.P * mask[:, None], -2), 2, 0)
    if direction == "in":  # scan columns: transpose planes, swap key fields
        key_plane = jnp.swapaxes(key_plane, 1, 2)
        cw = jnp.swapaxes(cw, 1, 2)
        pw = jnp.swapaxes(pw, 1, 2)
        # swap (ia, fa) <-> (ib, fb) inside packed keys so the kernel's
        # "row-owner" decode reads the destination fields
        occupied = key_plane != -1
        F = jnp.int32(cfg.F)
        fb = key_plane % F
        rest = key_plane // F
        fa = rest % F
        idx = rest // F
        ia, ib = idx // 16, idx % 16
        swapped = ((ib * 16 + ia) * F + fb) * F + fa
        key_plane = jnp.where(occupied, swapped, key_plane)

    pos = (pre.s[:, None] + pre.offs) % pre.width[:, None]
    lines = pre.start[:, None] + pos  # [B, r]

    def pad(x, fill=0):
        n = x.shape[0]
        p = (-n) % 128
        if p == 0:
            return x, n
        return jnp.pad(x, [(0, p)] + [(0, 0)] * (x.ndim - 1),
                       constant_values=fill), n

    linesP, n = pad(lines)
    fP, _ = pad(pre.f, fill=-3)  # never matches a real fingerprint
    leP, _ = pad(le_idx)
    w, wl = vertex_scan_kernel(linesP, fP, leP, key_plane, cw, pw,
                               r=cfg.r, F=cfg.F, c=cfg.c, interpret=interpret)
    w, wl = w[:n], wl[:n]

    # pool contribution
    col = 0 if direction == "out" else 1
    pm = state.pool_key[:, col][None, :] == pre.vid[:, None]
    maskk = valid_slot_mask(cfg, state, last).astype(state.pool_C.dtype)
    ptot = jnp.sum(state.pool_C * maskk, -1)
    w = w + jnp.sum(jnp.where(pm, ptot[None, :], 0), -1)
    plw = jnp.sum(state.pool_P * maskk[None, :, None], axis=1)  # [Q, c]
    lw = jnp.take_along_axis(
        jnp.broadcast_to(plw[None], (pre.vid.shape[0],) + plw.shape),
        le_idx[:, None, None].astype(jnp.int32), -1)[..., 0]
    wl = wl + jnp.sum(jnp.where(pm, lw, 0), -1)
    return w, wl
