"""Pallas kernel: blockwise-softmax (flash) attention for the LM substrate.

Standard IO-aware attention with explicit BlockSpec VMEM tiling:

  grid = (batch * q_heads, q_len // BQ, kv_len // BK)
  q tile   (BQ, dh)  revisited across the kv axis (Pallas keeps it in VMEM),
  k/v tile (BK, dh)  streamed,
  online-softmax running (m, l, acc) in VMEM scratch, f32 accumulation.

GQA is handled by the kv head index map (q head h reads kv head
h // group_size). The causal mask is applied from the absolute block
offsets; fully-masked kv blocks are skipped structurally by the grid lower
bound where possible (here: masked — Mosaic hoists the comparison).

MXU alignment: BQ/BK default 128, head_dim padded to a multiple of 128 by
the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, bq: int, bk: int, causal: bool, scale: float,
                kv_blocks: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # [bq, dh]
    k = k_ref[0].astype(jnp.float32)  # [bk, dh]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]
    if causal:
        qi = pl.program_id(1) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ki = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qi >= ki, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(kb == kv_blocks - 1)
    def _fin():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True, bq: int = 128,
                           bk: int = 128, interpret: bool = True):
    """q: [B, Hq, Lq, dh]; k/v: [B, Hkv, Lk, dh]. Returns [B, Hq, Lq, dh].

    Hq must be a multiple of Hkv (GQA); Lq % bq == 0, Lk % bk == 0.
    """
    B, Hq, Lq, dh = q.shape
    _, Hkv, Lk, _ = k.shape
    assert Hq % Hkv == 0 and Lq % bq == 0 and Lk % bk == 0
    group = Hq // Hkv
    qf = q.reshape(B * Hq, Lq, dh)
    kf = k.reshape(B * Hkv, Lk, dh)
    vf = v.reshape(B * Hkv, Lk, dh)
    kv_blocks = Lk // bk
    scale = 1.0 / (dh ** 0.5)

    out = pl.pallas_call(
        functools.partial(_flash_body, bq=bq, bk=bk, causal=causal,
                          scale=scale, kv_blocks=kv_blocks),
        grid=(B * Hq, Lq // bq, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Lq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max  m
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum  l
            pltpu.VMEM((bq, dh), jnp.float32),  # accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Lq, dh)
