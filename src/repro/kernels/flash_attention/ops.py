"""jit'd public wrapper for flash attention: padding + dispatch.

``attention(q, k, v, causal, impl)`` with impl in {"xla", "pallas",
"pallas_interpret"}. The models call this; smoke tests and the CPU dry-run
use the XLA path (identical math), TPU deployments flip the config flag.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention_kernel
from .ref import reference_attention


def _pad_len(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def attention(q, k, v, causal: bool = True, impl: str = "xla",
              bq: int = 128, bk: int = 128):
    if impl == "xla":
        return reference_attention(q, k, v, causal=causal)
    interpret = impl == "pallas_interpret"
    qp, lq = _pad_len(q, 2, bq)
    kp, lk = _pad_len(k, 2, bk)
    vp, _ = _pad_len(v, 2, bk)
    # padded kv columns must never win the softmax: causal mask handles the
    # q side; mask k padding by pushing keys to -inf via a large negative
    # bias is unnecessary here because padded keys are zeros and causal
    # masking already excludes out-of-range columns when lk == lq; for
    # cross-attention padding we mask explicitly:
    if not causal and lk != kp.shape[2]:
        raise ValueError("non-causal padding unsupported; pad kv upstream")
    out = flash_attention_kernel(qp, kp, vp, causal=causal, bq=bq, bk=bk,
                                 interpret=interpret)
    return out[:, :, :lq, :]
