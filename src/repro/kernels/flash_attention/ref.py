"""Pure-jnp oracle for flash attention (f32 softmax attention with GQA)."""

from __future__ import annotations

import jax.numpy as jnp


def reference_attention(q, k, v, causal: bool = True):
    """q: [B, Hq, Lq, dh]; k/v: [B, Hkv, Lk, dh] -> [B, Hq, Lq, dh]."""
    B, Hq, Lq, dh = q.shape
    _, Hkv, Lk, _ = k.shape
    group = Hq // Hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / (dh ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Lq, Lk), bool), k=Lk - Lq)
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(q.dtype)
