"""Heavy-hitter / top-k analytics over window-reduced QueryPlanes.

Three entry points — ``heavy_vertices_planes`` / ``heavy_edges_planes`` /
``top_labels_planes`` — with the same path contract as the query kernels:

  * ``interpret=True`` (CPU): pure-XLA decode twin, compiled, never the
    Pallas interpreter.
  * ``interpret=False`` (TPU): Pallas cell-decode kernel.
  * ``_kernel_interpret=True``: force the actual kernel body through the
    Pallas interpreter (bit-parity tests on CPU).
  * ``axis_name=...``: the same body runs inside ``shard_map`` — decode
    and flatten locally, ``all_gather`` the (identity, weight) rows, run
    the replicated epilogue. Per-identity totals are plain integer sums,
    so gather interleaving cannot change results: all paths bit-identical.

Top-k semantics (pinned against the fixed host reference in
``repro.core.analytics``): aggregate every occupied matrix cell *and*
every pool entry by decoded identity, rank by descending windowed weight,
break ties by ascending identity (lexicographic (src, dst) for edges).
Identities are int32 packed vids — edge identity is the *column pair*
(src, dst) ordered lexicographically, deliberately avoiding a packed
64-bit key so nothing here depends on x64 mode. Outputs are fixed-shape
``[k]`` arrays padded with (-1, 0) when fewer than k live identities
exist.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.heavy_hitters.kernel import (
    EMPTY, cell_decode_kernel_sharded, cell_decode_xla)


def _static_blocks(cfg) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    # pure-Python mirror of cfg.block_start_width() — static even when
    # called mid-trace (kernel grids and unrolls need Python ints)
    if cfg.block_bounds is not None:
        return (tuple(s for s, _ in cfg.block_bounds),
                tuple(w for _, w in cfg.block_bounds))
    return (tuple(i * cfg.b for i in range(cfg.n_blocks)),
            (cfg.b,) * cfg.n_blocks)


def decode_cell_owners(cfg, planes, *, interpret: bool = True,
                       _kernel_interpret: bool = False):
    """(vid_src, vid_dst) [S, 2, d, d] — decoded owners of every cell of
    the window-reduced planes, EMPTY (-1) where unoccupied."""
    starts, widths = _static_blocks(cfg)
    if interpret and not _kernel_interpret:
        return cell_decode_xla(planes.key, starts=jnp.asarray(starts),
                               widths=jnp.asarray(widths),
                               r=cfg.r, F=cfg.F)
    return cell_decode_kernel_sharded(
        planes.key, n_shards=planes.key.shape[0], starts=starts,
        widths=widths, r=cfg.r, F=cfg.F, interpret=interpret)


def _select_topk(vals, k: int):
    """k successive argmax extractions over ``vals`` (candidate totals,
    dead rows < 0). argmax's first-index tie rule is the ascending-identity
    tie break — callers arrange candidates in ascending identity order.
    Returns (idx [k], totals [k]) with (0-gather-safe idx, 0) padding;
    O(kN) elementwise, far faster on CPU than XLA's variadic top-k."""
    def body(i, carry):
        vals, idx, out = carry
        j = jnp.argmax(vals)
        idx = idx.at[i].set(j)
        out = out.at[i].set(jnp.maximum(vals[j], 0))
        return vals.at[j].set(jnp.int32(-1)), idx, out

    _, idx, out = jax.lax.fori_loop(
        0, k, body, (vals.astype(jnp.int32),
                     jnp.zeros((k,), jnp.int32), jnp.zeros((k,), jnp.int32)))
    return idx, out


def segment_topk(cols, w, k: int):
    """Aggregate rows by identity and take the top-k totals.

    cols: tuple of int32 [N] identity columns (lexicographic significance,
    most significant first); dead rows must carry negatives in *every*
    column. w: [N] int32 weights. Returns (tuple of [k] identity columns,
    [k] totals), descending total, ties ascending identity, (-1, 0)
    padding — deterministic for any row order because per-identity totals
    are order-free integer sums computed after a full sort by identity.

    Single-column identities take a fast path: XLA CPU's single-operand
    sort is ~4x the variadic (comparator-loop) sort, so instead of sorting
    (ident, w) together, sort ident alone, recover each row's group as its
    identity's first-occurrence index (``searchsorted`` into the sorted
    array), and scatter-add the weights onto those group anchors. The
    variadic lexicographic sort only remains for multi-column (edge)
    identities, which cannot be searchsorted.
    """
    w = jnp.where(cols[0] >= 0, w, 0).astype(jnp.int32)
    if len(cols) == 1:
        su = jnp.sort(cols[0].astype(jnp.int32))
        # first-occurrence index of each row's identity: a scatter target
        # that is unique per identity and ascending with it
        seg = jnp.searchsorted(su, cols[0].astype(jnp.int32))
        tot = jnp.zeros_like(w).at[seg].add(w)
        live = (su >= 0) & (tot > 0)
        idx, out_w = _select_topk(jnp.where(live, tot, jnp.int32(-1)), k)
        good = out_w > 0
        return (jnp.where(good, su[idx], jnp.int32(-1)),), out_w
    # one variadic lexicographic sort groups equal identities into runs
    # (ascending); w rides along as a non-key operand
    ops = jax.lax.sort(tuple(c.astype(jnp.int32) for c in cols) + (w,),
                       num_keys=len(cols), is_stable=True)
    sc, sw = list(ops[:-1]), ops[-1]
    neq = sc[0][1:] != sc[0][:-1]
    for c in sc[1:]:
        neq = neq | (c[1:] != c[:-1])
    start = jnp.concatenate([jnp.ones((1,), jnp.bool_), neq])
    end = jnp.concatenate([neq, jnp.ones((1,), jnp.bool_)])
    # per-run totals without scatters (XLA CPU scatter is serial): inclusive
    # cumsum, minus the run's base forward-filled by cummax — run bases are
    # nondecreasing (cumsum is), so max-scan over start-marked bases fills
    cs = jnp.cumsum(sw)
    run_base = jax.lax.cummax(jnp.where(start, cs - sw, 0))
    total = (cs - run_base).astype(jnp.int32)
    # a run's END row carries its full total; every run is one end row, in
    # ascending-lexicographic-identity order, matching _select_topk's tie
    # rule
    live = end & (sc[0] >= 0) & (total > 0)
    idx, out_w = _select_topk(jnp.where(live, total, jnp.int32(-1)), k)
    good = out_w > 0
    out_c = tuple(jnp.where(good, c[idx], jnp.int32(-1)) for c in sc)
    return out_c, out_w


def _flatten_rows(vids, planes, col: int):
    """Per-shard (identity, weight) rows: matrix cells then pool entries.
    vids: [S, 2, d, d] decoded owner side (or None to take pool column
    only via ``col``)."""
    S = planes.cw.shape[0]
    pool_live = planes.pool_cw > 0
    pid = jnp.where(pool_live, planes.pool_key[:, :, col], EMPTY)
    ident = jnp.concatenate([vids.reshape(S, -1), pid], axis=1).reshape(-1)
    w = jnp.concatenate([planes.cw.reshape(S, -1), planes.pool_cw],
                        axis=1).reshape(-1)
    return ident, w


def _gathered(arrs, axis_name):
    if axis_name is None:
        return arrs
    return [jax.lax.all_gather(a, axis_name, tiled=True) for a in arrs]


def heavy_vertices_planes(cfg, planes, k: int, *, direction: str = "out",
                          interpret: bool = True,
                          _kernel_interpret: bool = False,
                          axis_name=None):
    """Top-k (packed vid [k], weight [k]) by windowed out/in weight."""
    vs, vd = decode_cell_owners(cfg, planes, interpret=interpret,
                                _kernel_interpret=_kernel_interpret)
    col = 0 if direction == "out" else 1
    ident, w = _flatten_rows(vs if direction == "out" else vd, planes, col)
    ident, w = _gathered([ident, w], axis_name)
    (ids,), ws = segment_topk((ident,), w, k)
    return ids, ws


def heavy_edges_planes(cfg, planes, k: int, *, interpret: bool = True,
                       _kernel_interpret: bool = False, axis_name=None):
    """Top-k edges by windowed weight: (src [k], dst [k], weight [k])."""
    vs, vd = decode_cell_owners(cfg, planes, interpret=interpret,
                                _kernel_interpret=_kernel_interpret)
    src, w = _flatten_rows(vs, planes, 0)
    dst, _ = _flatten_rows(vd, planes, 1)
    src, dst, w = _gathered([src, dst, w], axis_name)
    (s, t), ws = segment_topk((src, dst), w, k)
    return s, t, ws


def top_labels_planes(cfg, planes, k: int, *, direction: str = "out",
                      interpret: bool = True,
                      _kernel_interpret: bool = False, axis_name=None):
    """Top-k (vertex-label block [k], weight [k]) by windowed out/in
    weight — the decoded vid's block id IS the label block."""
    vs, vd = decode_cell_owners(cfg, planes, interpret=interpret,
                                _kernel_interpret=_kernel_interpret)
    col = 0 if direction == "out" else 1
    vid, w = _flatten_rows(vs if direction == "out" else vd, planes, col)
    # floor division keeps dead rows negative (-1 // span == -1)
    blk = vid // jnp.int32(2048 * cfg.F)
    blk, w = _gathered([blk, w], axis_name)
    (blocks,), ws = segment_topk((blk,), w, k)
    return blocks, ws
