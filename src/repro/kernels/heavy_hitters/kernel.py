"""Pallas kernel: cell-owner decode for the heavy-hitter portfolio.

The reversible-sketch trick (gMatrix, arXiv 1510.02219): every occupied
cell's stored key carries (candidate index, fingerprint) for both
endpoints, so the packed vertex identities of the cell's source and
destination are recoverable in closed form — no raw-id table. The kernel
decodes all ``2 * d * d`` cells of a shard's window-reduced planes in one
VPU pass: unpack the key fields, replay the ``r``-step LCG candidate
chain (static unroll, select at the stored index), invert the modular
address, pack ``(block, address, fingerprint)``. The top-k aggregation
over the decoded owners is matmul/sort-shaped and stays in XLA
(``ops.segment_topk``); the per-cell integer decode is the kernelizable
middle.

Grid = shards; one shard's planes are VMEM-resident per step, exactly
like ``sketch_query``/``vertex_scan``. ``cell_decode_xla`` is the
compiled pure-XLA twin (the production CPU route — the pallas path never
interprets) built on ``hashing.decode_line_vid``, the same shared
reversibility seam ``reshard``/BFS/host-analytics use; results are
bit-identical (integer ops only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EMPTY = -1
IDX_RADIX = 16
# LCG family constants — must mirror repro.core.hashing (bit-parity)
LCG_T = 1103515245
LCG_I = 12345
M_MASK = 0x7FFFFFFF


def _chain_select(f, idx, r: int):
    """offs(f)[idx]: the idx-th entry of the LCG candidate chain seeded by
    fingerprint f — static unroll with a where-select, elementwise over
    any shape (the in-kernel twin of ``hashing.candidate_offsets`` +
    ``take_along_axis``)."""
    t = jnp.uint32(LCG_T)
    inc = jnp.uint32(LCG_I)
    mask = jnp.uint32(M_MASK)
    x = (t * f.astype(jnp.uint32) + inc) & mask
    sel = jnp.zeros_like(f)
    for i in range(r):
        sel = jnp.where(idx == i, x.astype(jnp.int32), sel)
        x = (t * x + inc) & mask
    return sel


def _block_lookup(line, starts, widths):
    """(start, width) of the label block containing an absolute line index
    — static unroll over the (ascending) block partition."""
    start = jnp.full_like(line, starts[0])
    width = jnp.full_like(line, widths[0])
    blk = jnp.zeros_like(line)
    for b in range(1, len(starts)):
        ge = line >= starts[b]
        start = jnp.where(ge, starts[b], start)
        width = jnp.where(ge, widths[b], width)
        blk = jnp.where(ge, b, blk)
    return blk, start, width


def _decode_side(lines, idx, f, starts, widths, r: int, F: int):
    blk, start, width = _block_lookup(lines, starts, widths)
    sel = _chain_select(f, idx, r)
    s = (lines - start - sel) % width
    return (blk * jnp.int32(2048) + s) * jnp.int32(F) + f


def _decode_body(key_ref, vs_ref, vd_ref, *, starts, widths, r: int, F: int):
    tl = (0,) * (key_ref.ndim - 3)  # plane tiles trailing (2, d, d)
    k = key_ref[(*tl, slice(None), slice(None), slice(None))]  # [2, d, d]
    fb = k % jnp.int32(F)
    rest = k // jnp.int32(F)
    fa = rest % jnp.int32(F)
    idx = rest // jnp.int32(F)
    ia = idx // jnp.int32(IDX_RADIX)
    ib = idx % jnp.int32(IDX_RADIX)
    rows = jax.lax.broadcasted_iota(jnp.int32, k.shape, k.ndim - 2)
    cols = jax.lax.broadcasted_iota(jnp.int32, k.shape, k.ndim - 1)
    occ = k != EMPTY
    vs = _decode_side(rows, ia, fa, starts, widths, r, F)
    vd = _decode_side(cols, ib, fb, starts, widths, r, F)
    sl = (*tl, slice(None), slice(None), slice(None))
    vs_ref[sl] = jnp.where(occ, vs, EMPTY)
    vd_ref[sl] = jnp.where(occ, vd, EMPTY)


@functools.partial(jax.jit, static_argnames=("n_shards", "starts", "widths",
                                             "r", "F", "interpret"))
def cell_decode_kernel_sharded(key_plane, *, n_shards: int, starts, widths,
                               r: int, F: int, interpret: bool = True):
    """Decode every cell's (source, destination) packed vids per shard.

    key_plane: [n_shards, 2, d, d] twin-leading packed keys (QueryPlanes
    layout). ``starts``/``widths``: the static block partition as tuples.
    Returns (vid_src, vid_dst), each [n_shards, 2, d, d] with EMPTY (-1)
    on unoccupied cells. Grid ``(n_shards,)`` — one shard's planes
    VMEM-resident per step.
    """
    grid = (n_shards,)
    plane = pl.BlockSpec((1,) + key_plane.shape[1:], lambda h: (h, 0, 0, 0))
    vs, vd = pl.pallas_call(
        functools.partial(_decode_body, starts=starts, widths=widths,
                          r=r, F=F),
        grid=grid,
        in_specs=[plane],
        out_specs=[plane, plane],
        out_shape=[
            jax.ShapeDtypeStruct(key_plane.shape, jnp.int32),
            jax.ShapeDtypeStruct(key_plane.shape, jnp.int32),
        ],
        interpret=interpret,
    )(key_plane)
    return vs, vd


def cell_decode_xla(key_plane, *, starts, widths, r: int, F: int):
    """Compiled pure-XLA twin of ``cell_decode_kernel_sharded`` — the same
    closed-form inversion via the shared ``hashing.decode_line_vid`` seam;
    bit-identical (integer ops only). key_plane: [S, 2, d, d] twin-leading.
    Traced (not jitted) — compose inside a jitted caller.
    """
    from repro.core import hashing as hsh

    d = key_plane.shape[-1]
    ia, ib, fa, fb = hsh.unpack_key(key_plane, F)
    rows = jnp.arange(d, dtype=jnp.int32)[None, None, :, None]
    cols = jnp.arange(d, dtype=jnp.int32)[None, None, None, :]
    vs = hsh.decode_line_vid(rows, ia, fa, starts, widths, r, F)
    vd = hsh.decode_line_vid(cols, ib, fb, starts, widths, r, F)
    occ = key_plane != EMPTY
    return jnp.where(occ, vs, EMPTY), jnp.where(occ, vd, EMPTY)
